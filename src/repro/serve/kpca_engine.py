"""Batched kPCA projection-serving engine (fit once, serve many).

The serving workload is the mirror image of ``DecodeEngine``: stateless
per-query math instead of a KV cache, so the engine's whole job is shaping
traffic for the compiled step. Variable-size requests are packed head-to-
tail into fixed-width slabs and padded up to POWER-OF-TWO shape buckets, so
a bounded set of compiled programs (log2(max_batch) of them) serves any
request mix with zero recompiles in steady state — the classic bucketing
trick from LM serving applied to kernel projection. The queue/bucket/slab
machinery itself lives in ``repro.serve.batching`` (shared with the decode
engine).

The request path is an ASYNC pipeline: ``submit`` returns a
``concurrent.futures`` future immediately; a background flusher thread
(``start``/``close``) drains the queue on a size-OR-deadline trigger and
resolves the futures, so query batching overlaps with callers' work the
same way the solver overlaps computation with communication. ``flush`` is
the synchronous drain (same packing, same math — the async path is
result-exact against it), and ``project_many`` the one-call convenience.

Guarantees and knobs:
  * results are exactly what ``repro.core.oos.project`` returns for each
    request alone — padding rows are sliced off and row-wise kernel math
    makes valid rows independent of them (asserted to float32 resolution in
    tests/test_kpca_engine.py; the only packing residue is XLA choosing a
    different gemm code path per slab shape, <= 4e-9 observed);
  * admission control: ``queue_factor=k`` bounds the queue at
    ``max_batch * k`` rows — beyond it ``submit`` rejects
    (``QueueFullError``) or sheds the oldest queued requests, per
    ``cfg.admission``; counters surface in ``EngineStats``;
  * ``use_pallas`` routes through the fused Pallas projection kernel;
  * ``query_dtype=jnp.bfloat16`` halves query-slab HBM traffic (accumulation
    stays fp32 inside the kernel) for throughput-bound fleets;
  * per-request latency/queue-wait and queries/s accounting built in
    (served straight into benchmarks/bench_serve_async.py).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import oos
from ..core.oos import FittedKpca, ShardedFittedKpca
from ..faults.errors import DeadlineExceededError
from ..obs import metrics, trace
from .batching import (EngineStats, QueueFullError, RequestFuture,
                       RequestQueue, RequestStats, iter_slabs, pow2_buckets)
from .publisher import ModelHandle


@dataclasses.dataclass
class KpcaServeConfig:
    max_batch: int = 128          # widest bucket = compiled slab width
    min_bucket: int = 8           # narrowest bucket (absorbs tiny tails)
    use_pallas: bool = False      # fused Pallas kernel (interpret off-TPU)
    query_dtype: Any = None       # e.g. jnp.bfloat16 for cheaper slabs
    interpret: Optional[bool] = None  # forwarded to the Pallas wrapper
    # -- async flusher / admission control --------------------------------
    queue_factor: Optional[int] = None  # queue bound = max_batch * k rows;
    #                                     None = unbounded, no admission
    admission: str = "reject"     # "reject" new or "shed" oldest when full
    flush_max_wait_s: float = 0.005   # deadline trigger: max queue wait of
    #                                   the oldest request before a flush
    flush_min_queries: Optional[int] = None  # size trigger (None: max_batch)
    # -- fault tolerance (docs/FAULT_TOLERANCE.md) -------------------------
    max_retries: int = 0          # extra serve attempts per drain; 0 keeps
    #                               the fail-fast contract (a failed batch
    #                               fails exactly its own futures)
    retry_backoff_s: float = 0.02     # base backoff, doubled per attempt
    #                                   (skipped when on_fault healed it)
    request_deadline_s: Optional[float] = None  # submit -> serve budget;
    #                               expired requests fail with
    #                               DeadlineExceededError instead of being
    #                               served late (None = no deadline)

    def buckets(self) -> List[int]:
        """Power-of-two widths: min_bucket, 2*min_bucket, ..., max_batch."""
        return pow2_buckets(self.min_bucket, self.max_batch)

    def queue_capacity(self) -> Optional[int]:
        if self.queue_factor is None:
            return None
        if self.queue_factor < 1:
            raise ValueError(
                f"queue_factor must be >= 1, got {self.queue_factor}")
        return self.max_batch * self.queue_factor


class KpcaEngine:
    """Micro-batching projection server over a fitted kPCA artifact.

    Accepts either a single-device ``FittedKpca`` (scored via
    ``repro.core.oos.project``) or a multi-device ``ShardedFittedKpca``
    (scored via ``repro.serve.sharded.project_sharded``: per-shard partials
    under shard_map, psum, global centering applied once post-reduction).
    The batching/bucketing layer is identical for both — slabs are
    replicated to every shard, so the engine's traffic shaping composes
    with device sharding unchanged.

    Request API: ``submit`` enqueues and returns a future; results arrive
    when a drain happens — synchronously via ``flush`` (or ``project_many``),
    or from the background flusher thread between ``start`` and ``close``
    (the engine is also a context manager doing exactly that). Both drains
    run the same packing and the same compiled programs, so async results
    are exact against the synchronous path.

    Live updates: the engine reads its model THROUGH a versioned
    ``repro.serve.publisher.ModelHandle`` (a bare model is wrapped in a
    private one). Each drain snapshots (model, version) once, so every
    slab of that drain — and therefore every in-flight request — is scored
    against one consistent version even if a publish lands mid-drain; the
    next drain picks up the new version. For sharded models a per-shard
    coefficient refresh is still one atomic whole-model publish
    (``ModelHandle.refresh_shard``), so no request can ever see a mix of
    shard versions. ``RequestStats.model_version`` records which version
    served each request.
    """

    def __init__(self,
                 model: Union[FittedKpca, ShardedFittedKpca, ModelHandle],
                 cfg: KpcaServeConfig = None, mesh=None,
                 inject_fault=None, on_fault=None):
        """Args:
          model: servable artifact (plain or sharded) or a ``ModelHandle``
            wrapping one (live-publishable).
          cfg: batching/bucketing/backend/admission knobs
            (``KpcaServeConfig``).
          mesh: for sharded models only — 1-D device mesh with
            ``model.n_shards`` devices; None builds one over local devices
            (or falls back to a same-math single-device reduction).
          inject_fault: optional ``model -> None`` hook called at the top
            of every drain attempt with the snapshotted model; raising
            aborts the attempt. The deterministic chaos tests use it
            (``repro.faults.serving.ShardLossInjector``) to stand in for
            a dead shard host — production engines leave it None.
          on_fault: optional ``(exc, handle) -> bool`` recovery hook
            called when a drain attempt fails and retries remain.
            Returning True means "handled — retry immediately" (e.g.
            ``ShardRebalancer`` republished a survivor model, which the
            next attempt picks up because every attempt re-reads the
            handle); False falls back to exponential backoff.
        """
        self.handle = model if isinstance(model, ModelHandle) \
            else ModelHandle(model)
        model = self.handle.current()
        self.cfg = cfg or KpcaServeConfig()
        self._inject_fault = inject_fault
        self._on_fault = on_fault
        self._buckets = self.cfg.buckets()
        # _dispatch_lock orders concurrent drains' device programs; it is
        # held only across the (async) dispatch calls, never across a
        # device sync — the blocking host<->device copies happen outside
        # it (see _serve). _stats_lock guards the host-side accounting
        # that submitters and drains both touch.
        self._dispatch_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._compiled_shapes = set()         # guarded-by: _stats_lock
        self.stats = EngineStats()            # guarded-by: _stats_lock
        self._queue = RequestQueue(max_queries=self.cfg.queue_capacity(),
                                   policy=self.cfg.admission)
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        # Cached metric handles, resolved once: the hot path must not pay
        # a registry lookup per drain (and pays nothing per submit — all
        # metric publication happens at the per-drain commit point).
        self._m_requests = metrics.counter(
            "serve_requests_total", "Requests served")
        self._m_queries = metrics.counter(
            "serve_queries_total", "Query rows served")
        self._m_padded = metrics.counter(
            "serve_padded_rows_total", "Wasted pad rows computed")
        self._m_rejected = metrics.counter(
            "serve_rejected_total", "Admissions refused (QueueFullError)")
        self._m_shed = metrics.counter(
            "serve_shed_total", "Queued requests shed to admit newer ones")
        self._m_flushes = metrics.counter(
            "serve_flushes_total", "Drain cycles that served >= 1 request")
        self._m_depth = metrics.gauge(
            "serve_queue_depth_rows", "Queued rows after the last drain")
        self._m_version = metrics.gauge(
            "serve_model_version", "Model version the last drain served")
        self._m_latency = metrics.histogram(
            "serve_request_latency_seconds", "Per-request device wall time")
        self._m_wait = metrics.histogram(
            "serve_queue_wait_seconds", "Submit -> start-of-serve wait")
        self._m_retries = metrics.counter(
            "serve_retries_total", "Drain attempts retried after a fault")
        self._m_expired = metrics.counter(
            "serve_deadline_expired_total",
            "Requests failed on the per-request deadline")

        if isinstance(model, ShardedFittedKpca):
            from .sharded import project_sharded
            from ..launch.mesh import make_serving_mesh
            if mesh is None:
                mesh = make_serving_mesh(model.n_shards)

            def _proj(m, xq):
                return project_sharded(m, xq, mesh=mesh,
                                       use_pallas=self.cfg.use_pallas,
                                       interpret=self.cfg.interpret)
        else:
            if mesh is not None:
                raise ValueError("mesh is only meaningful for a "
                                 "ShardedFittedKpca model")

            def _proj(m, xq):
                return oos.project(m, xq, use_pallas=self.cfg.use_pallas,
                                   interpret=self.cfg.interpret)

        self._proj = jax.jit(_proj)

    @property
    def model(self):
        """The live model (read through the handle)."""
        return self.handle.current()

    # ---- request API -----------------------------------------------------

    def submit(self, x_query) -> RequestFuture:
        """Enqueue one request; returns its result future immediately.

        Args:
          x_query: (Q, M) array-like, M = model.n_features; cast to fp32
            host-side (the engine re-casts per ``cfg.query_dtype`` at slab
            build time).

        Returns:
          A ``concurrent.futures`` future resolving to this request's
          (Q, C) float32 scores at the next drain — the background
          flusher's (when running) or an explicit ``flush``. The future
          also carries ``request_id``, the request's key in the dict
          ``flush`` returns.

        Raises:
          QueueFullError: admission control refused the request
            (``cfg.queue_factor`` bound exceeded under policy "reject", or
            the request alone exceeds the whole queue capacity).
        """
        x = np.asarray(x_query, np.float32)
        if x.ndim != 2 or x.shape[1] != self.model.n_features:
            raise ValueError(
                f"request must be (Q, {self.model.n_features}), "
                f"got {x.shape}")
        try:
            fut, shed = self._queue.put(x, n=x.shape[0])
        except QueueFullError:
            with self._stats_lock:
                self.stats.n_rejected += 1
            self._m_rejected.inc()
            trace.instant("serve.rejected", n=x.shape[0])
            raise
        if shed:
            with self._stats_lock:
                self.stats.n_shed += len(shed)
            self._m_shed.inc(len(shed))
        return fut

    def flush(self) -> dict:
        """Serve every queued request synchronously; resolves the futures
        and returns {request_id: (Q, C) scores}.

        On failure (after ``cfg.max_retries`` attempts) the still-live
        queued requests are restored (ahead of anything submitted
        meanwhile), so a crashed flush can simply be retried. Requests
        past ``cfg.request_deadline_s`` fail with
        ``DeadlineExceededError`` instead of being restored.
        """
        entries = self._queue.drain()
        if not entries:
            return {}
        entries = list(entries)
        try:
            out, served = self._serve_with_recovery(entries)
        except BaseException:
            # `entries` was pruned in place: expired futures are already
            # failed and must not re-enter the queue.
            self._queue.restore(entries)
            raise
        self._resolve(served, out)
        return out

    def project_many(self, requests: Sequence[Any]) -> List[np.ndarray]:
        """Convenience: submit + flush a list of (Q_i, M) arrays; returns
        the per-request (Q_i, C) score arrays in submission order."""
        futs = [self.submit(x) for x in requests]
        self.flush()
        return [f.result() for f in futs]

    # ---- background flusher ----------------------------------------------

    def start(self) -> "KpcaEngine":
        """Start the background flusher thread (idempotent).

        The flusher sleeps on the queue and drains it whenever either
        trigger fires: queued rows reach ``cfg.flush_min_queries``
        (default: one full ``max_batch`` slab), or the oldest request has
        waited ``cfg.flush_max_wait_s``. A failed drain fails exactly the
        futures of that batch (no retry loop) and keeps serving.
        """
        if self._flusher is not None:
            return self
        self._stop.clear()
        self._flusher = threading.Thread(
            target=self._flush_loop, name="kpca-engine-flusher", daemon=True)
        self._flusher.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Stop the flusher thread (joined) and settle the queue: serve
        everything still queued when ``drain`` (default), else cancel the
        pending futures. Safe to call twice; ``flush``/``submit`` keep
        working afterwards (synchronous mode)."""
        if self._flusher is not None:
            self._stop.set()
            self._queue.kick()
            self._flusher.join(timeout=30.0)
            if self._flusher.is_alive():       # pragma: no cover
                raise RuntimeError("flusher thread failed to stop")
            self._flusher = None
        if drain:
            self.flush()
        else:
            for e in self._queue.drain():
                e.future.cancel()

    @property
    def running(self) -> bool:
        return self._flusher is not None

    def __enter__(self) -> "KpcaEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=exc[0] is None)

    def _flush_loop(self) -> None:
        trigger = self.cfg.flush_min_queries or self.cfg.max_batch
        while True:
            has_work = self._queue.wait_for_work(
                trigger, self.cfg.flush_max_wait_s, self._stop)
            if self._stop.is_set():
                return                # close() settles whatever remains
            if not has_work:
                continue
            entries = self._queue.drain()
            if not entries:
                continue
            entries = list(entries)
            try:
                out, served = self._serve_with_recovery(entries)
            except BaseException as e:       # fail THIS batch, keep serving
                for en in entries:
                    if not en.future.done():
                        en.future.set_exception(e)
                continue
            self._resolve(served, out)

    @staticmethod
    def _resolve(entries, out: dict) -> None:
        with trace.span("serve.resolve", n_requests=len(entries)):
            for e in entries:
                if not e.future.done():      # skip caller-cancelled futures
                    e.future.set_result(out[e.rid])

    # ---- internals -------------------------------------------------------

    def _expire(self, entries: list) -> list:
        """Split off deadline-expired requests; their futures fail NOW
        with ``DeadlineExceededError`` (typed, never served late).
        Returns the still-live entries."""
        ddl = self.cfg.request_deadline_s
        if ddl is None:
            return entries
        now = time.monotonic()
        live, n_expired = [], 0
        for e in entries:
            waited = now - e.t_submit
            if waited > ddl:
                n_expired += 1
                if not e.future.done():
                    e.future.set_exception(DeadlineExceededError(waited, ddl))
            else:
                live.append(e)
        if n_expired:
            with self._stats_lock:
                self.stats.n_deadline_expired += n_expired
            self._m_expired.inc(n_expired)
            if trace.is_enabled():
                trace.instant("serve.deadline_expired", n=n_expired)
        return live

    def _serve_with_recovery(self, entries: list) -> tuple:
        """``_serve`` under the fault-tolerance contract: drop expired
        requests before every attempt, retry up to ``cfg.max_retries``
        times after a failure (invoking ``on_fault`` between attempts —
        every attempt re-reads the handle, so a recovery publish heals
        the retry), and raise only once retries are exhausted.

        Prunes ``entries`` IN PLACE to the still-live subset (callers
        use it for restore-on-error) and returns ``(out, served)``.
        With ``max_retries=0`` and no deadline this is exactly one
        ``_serve`` call — the pre-fault-layer behavior.
        """
        attempt = 0
        while True:
            live = self._expire(entries)
            entries[:] = live
            if not live:
                return {}, []
            try:
                return self._serve(live), live
            except BaseException as e:
                if attempt >= self.cfg.max_retries:
                    raise
                attempt += 1
                handled = False
                if self._on_fault is not None:
                    # A recovery-hook crash must not eat the original
                    # fault: log it into the trace and fall back to
                    # plain backoff.
                    try:
                        handled = bool(self._on_fault(e, self.handle))
                    except BaseException:
                        handled = False
                with self._stats_lock:
                    self.stats.n_retries += 1
                self._m_retries.inc()
                if trace.is_enabled():
                    trace.instant("serve.retry", attempt=attempt,
                                  error=type(e).__name__, handled=handled)
                if not handled:
                    # Interruptible backoff: close() must not wait it out.
                    self._stop.wait(
                        self.cfg.retry_backoff_s * (2 ** (attempt - 1)))

    def _serve(self, entries) -> dict:
        # One consistent (model, version) snapshot for the whole drain:
        # in-flight slabs finish on it even if a publish lands mid-drain.
        model, version = self.handle.get()
        if self._inject_fault is not None:
            self._inject_fault(model)
        t_start = time.monotonic()

        # Three-phase drain so no device sync ever happens under a lock:
        #   1. pack + host->device staging (no lock);
        #   2. dispatch every slab under _dispatch_lock — jit dispatch is
        #      ASYNC, so the critical section is microseconds and only
        #      orders concurrent drains' device programs;
        #   3. blocking device->host gets (no lock), then one stats commit.
        with trace.span("serve.pack", n_requests=len(entries)):
            slabs = list(iter_slabs(entries, self.cfg.max_batch,
                                    self._buckets))
            staged = [self._stage_slab(slab) for slab, _, _ in slabs]
        with trace.span("serve.dispatch", n_slabs=len(slabs)):
            with self._dispatch_lock:
                launched = [self._run_slab(model, xq) for xq in staged]

        results = {e.rid: [] for e in entries}
        touched = {e.rid: 0.0 for e in entries}
        total_dt, padded = 0.0, 0
        with trace.span("serve.device", n_slabs=len(slabs)):
            for (slab, take, span_owners), dev in zip(slabs, launched):
                t0 = time.perf_counter()
                scores = np.asarray(dev)         # waits for this slab
                dt = time.perf_counter() - t0
                padded += slab.shape[0] - take
                total_dt += dt
                for rid in np.unique(span_owners):
                    sel = span_owners == rid
                    results[rid].append(scores[:take][sel])
                    touched[rid] += dt

        # Commit only after every slab resolved, so a failed-then-retried
        # flush doesn't double-count its slabs.
        waits = [max(0.0, t_start - e.t_submit) for e in entries]
        with self._stats_lock:
            self.stats.n_padded += padded
            self.stats.total_time_s += total_dt
            self.stats.n_requests += len(entries)
            self.stats.n_queries += sum(e.n for e in entries)
            self.stats.n_flushes += 1
            for e, wait in zip(entries, waits):
                self.stats.per_request.append(RequestStats(
                    e.rid, e.n, touched[e.rid], version, queue_wait_s=wait))
        # Metric publication rides the same per-drain commit point (one
        # batch of updates per drain, nothing on the submit hot path).
        self._m_requests.inc(len(entries))
        self._m_queries.inc(sum(e.n for e in entries))
        self._m_padded.inc(padded)
        self._m_flushes.inc()
        self._m_depth.set(self._queue.depth)
        self._m_version.set(version)
        self._m_latency.observe_many(list(touched.values()))
        self._m_wait.observe_many(waits)
        if trace.is_enabled():
            for e, wait in zip(entries, waits):
                # Backdated complete event: the submit->serve gap renders
                # as its own "queue_wait" phase without any submit-side
                # instrumentation.
                trace.complete("serve.queue_wait", wait, rid=e.rid, n=e.n)
        empty = np.zeros((0, model.n_components), np.float32)
        return {rid: np.concatenate(parts, axis=0) if parts else empty
                for rid, parts in results.items()}

    def _stage_slab(self, slab: np.ndarray) -> jax.Array:
        """Host->device transfer + dtype cast for one packed slab (phase 1
        of a drain — runs outside every lock)."""
        xq = jnp.asarray(slab)
        if self.cfg.query_dtype is not None:
            xq = xq.astype(self.cfg.query_dtype)
        with self._stats_lock:
            if xq.shape not in self._compiled_shapes:
                self._compiled_shapes.add(xq.shape)
                self.stats.n_compiles += 1
        return xq

    def _run_slab(self, model, xq) -> jax.Array:
        """Dispatch one staged slab (async; the caller owns the blocking
        device->host get)."""
        return self._proj(model, jnp.asarray(xq))


__all__ = ["EngineStats", "KpcaEngine", "KpcaServeConfig", "QueueFullError",
           "RequestFuture", "RequestStats"]
