import os
from . import env as _env
_env.apply(_env.EnvConfig(host_devices=512))
_env.apply_from_environ()
# ^ MUST precede every jax-importing import: jax locks the device count on
# first init. The dry-run (and ONLY the dry-run) builds the production
# mesh from 512 placeholder host devices; smoke tests and benches see the
# default 1. REPRO_* variables may still override (env.apply merges, the
# user's explicit XLA_FLAGS win).

"""Multi-pod dry-run: .lower().compile() every (architecture x input shape)
cell on the single-pod (16,16) mesh AND the multi-pod (2,16,16) mesh,
recording memory analysis, FLOPs/bytes, and the collective schedule for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k --multipod both --out results/dryrun.json

Results are written incrementally (--resume skips completed cells) — the
dry-run itself is restartable, like everything else in this repo."""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import (ARCH_NAMES, SHAPES, applicable, get_config,  # noqa: E402
                       train_batch_specs)
from ..distributed.sharding import default_rules, spec_for  # noqa: E402
from ..models import build_model  # noqa: E402
from ..models.common import abstract_params  # noqa: E402
from ..optim import AdamWConfig, adamw_update  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# dtype byte sizes for HLO shape strings like f32[16,512]{1,0}
_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
             "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _hlo_collective_bytes(hlo_text: str):
    """Sum OUTPUT operand bytes of every collective op in the (per-device)
    SPMD module, grouped by op kind. Conservative wire model documented in
    EXPERIMENTS.md §Roofline."""
    out = {}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*([\w\[\](){},\s]*?)\s"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt not in _DT_BYTES:
                continue
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            nbytes += size * _DT_BYTES[dt]
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += nbytes
    return out


def _shardings_tree(shapes, axes, rules, mesh):
    return {k: NamedSharding(mesh, spec_for(shapes[k].shape, axes[k], rules,
                                            mesh))
            for k in shapes}


def _with_sharding(sds, sharding):
    return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sharding)


def _batch_shardings(batch_specs, rules, mesh):
    out = {}
    for k, v in batch_specs.items():
        dims = [rules["batch"]] + [None] * (len(v.shape) - 1)
        out[k] = _with_sharding(v, NamedSharding(mesh, P(*dims)))
    return out


def _cache_sharded(cache_abstract, cfg, rules, mesh):
    """Heuristic cache shardings: batch dim -> data axes; the longest
    (sequence/state) dim -> 'model' when divisible (context-parallel
    decode); everything else replicated."""
    batch_axes = rules["batch"]

    def shard_one(sds):
        shape = sds.shape
        spec = [None] * len(shape)
        # batch: stacked caches are (L, B, ...); enc_out is (B, ...)
        bdim = 1 if len(shape) >= 3 else 0
        bsz = int(np.prod([mesh.shape[a] for a in
                           ((batch_axes,) if isinstance(batch_axes, str)
                            else batch_axes)]))
        if shape[bdim] % bsz == 0:
            spec[bdim] = batch_axes
        # longest remaining dim -> model (KV seq / d_inner)
        rest = [(d, i) for i, d in enumerate(shape) if i != bdim]
        if rest:
            d, i = max(rest)
            if d % mesh.shape["model"] == 0 and d >= mesh.shape["model"]:
                spec[i] = "model"
        return _with_sharding(sds, NamedSharding(mesh, P(*spec)))

    return jax.tree.map(shard_one, cache_abstract)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    error: str = ""
    compile_s: float = 0.0
    flops_per_device: float = 0.0
    bytes_accessed_per_device: float = 0.0
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0
    alias_bytes: int = 0
    collectives: dict = dataclasses.field(default_factory=dict)
    n_devices: int = 0
    n_params: float = 0.0
    n_active_params: float = 0.0
    # raw (full-depth compile) numbers: XLA's cost model counts a while-loop
    # (scan) body ONCE, so these undercount by ~n_layers; the headline
    # flops/bytes/collectives fields are depth-extrapolated (see
    # _depth_extrapolate) which is exact for scanned layer stacks.
    flops_raw: float = 0.0
    bytes_raw: float = 0.0
    collectives_raw: dict = dataclasses.field(default_factory=dict)
    depth_points: list = dataclasses.field(default_factory=list)


def depth_pair(cfg):
    """Two reduced depths for linear cost extrapolation (exact for scanned
    stacks; <1% error for the hybrid tail)."""
    if cfg.attn_every > 0:                       # hybrid: whole groups
        return (cfg.attn_every, 2 * cfg.attn_every)
    fkd = cfg.first_k_dense
    return (fkd + 2, fkd + 4)


def scale_depth(cfg, n_layers: int):
    """Cost variant: reduced depth + every internal scan unrolled so the XLA
    cost model sees all iterations."""
    upd = {"n_layers": n_layers, "unroll_scans": True}
    if cfg.is_encdec:
        upd["n_enc_layers"] = n_layers           # seamless: enc == dec == 24
    return dataclasses.replace(cfg, **upd)


def seq_points(cfg, shape):
    """Three token lengths for the quadratic seq fit — aligned to attention
    chunk (1024) and ssm chunk granularity, above the VLM frontend prefix.
    Mamba archs use shorter points: their cost variants unroll the per-chunk
    time scans, and seq/64 unrolled SSD bodies at 4096 tokens make XLA
    compile times explode; the quadratic fit is length-invariant."""
    if os.environ.get("REPRO_SEQ_PTS"):
        pts = tuple(int(x) for x in os.environ["REPRO_SEQ_PTS"].split(","))
    elif cfg.mamba_version:
        pts = (128, 192, 256)
    elif cfg.family == "vlm" and cfg.frontend_seq:
        pts = (2048, 3072, 4096)
    else:
        pts = (1024, 2048, 4096)
    return tuple(min(p, shape.seq) for p in pts) \
        if shape.seq >= pts[-1] else (shape.seq,) * 3


def _lin(l1, f1, l2, f2, full):
    """Linear extrapolation f(L) = f1 + (f2-f1)*(L-L1)/(L2-L1)."""
    return f1 + (f2 - f1) * (full - l1) / max(l2 - l1, 1)


def resolve_cfg(arch: str, shape_name: str, attention_impl: str = None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    if attention_impl:
        cfg = dataclasses.replace(cfg, attention_impl=attention_impl)
    elif shape.kind == "prefill":
        # memory-bounded flash-style attention for long prefill (baseline
        # serving-stack choice; see EXPERIMENTS.md §Perf)
        cfg = dataclasses.replace(cfg, attention_impl="chunked")
    return cfg, shape


def prepare_cell(arch: str, shape_name: str, multi_pod: bool,
                 attention_impl: str = None, rules_overrides: dict = None,
                 cfg=None, seq: int = None):
    """Build (lower_fn) for one cell; returns a thunk that lowers+compiles."""
    cfg_r, shape = resolve_cfg(arch, shape_name, attention_impl)
    if cfg is None:
        cfg = cfg_r
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod)
    if rules_overrides:
        rules.update(rules_overrides)
    for k, v in cfg.sharding_overrides:
        rules[k] = v
    model = build_model(cfg, mesh=mesh)

    pshapes, paxes = abstract_params(
        lambda k: model.init(k), jax.random.PRNGKey(0))
    psh = _shardings_tree(pshapes, paxes, rules, mesh)
    params_abs = {k: _with_sharding(v, psh[k]) for k, v in pshapes.items()}

    if shape.kind == "train":
        batch_abs = _batch_shardings(train_batch_specs(cfg, shape, seq=seq),
                                     rules, mesh)
        opt_cfg = AdamWConfig()

        def train_step(state, batch):
            def loss_fn(p):
                return model.loss(p, batch)
            (loss, _), grads = jax.value_and_grad(loss_fn,
                                                  has_aux=True)(
                state["params"])
            new_p, opt, _ = adamw_update(opt_cfg, state["params"], grads, {
                "m": state["m"], "v": state["v"], "step": state["step"]})
            return {"params": new_p, "m": opt["m"], "v": opt["v"],
                    "step": opt["step"]}, loss

        fstate = {
            "params": params_abs,
            "m": {k: _with_sharding(jax.ShapeDtypeStruct(v.shape,
                                                         jnp.float32),
                                    psh[k]) for k, v in pshapes.items()},
            "v": {k: _with_sharding(jax.ShapeDtypeStruct(v.shape,
                                                         jnp.float32),
                                    psh[k]) for k, v in pshapes.items()},
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        fn = jax.jit(train_step, donate_argnums=(0,))
        args = (fstate, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = _batch_shardings(train_batch_specs(cfg, shape, seq=seq),
                                     rules, mesh)
        cache_len_target = seq or shape.seq

        def prefill_step(params, batch):
            return model.prefill(params, batch, max_len=cache_len_target)

        fn = jax.jit(prefill_step)
        args = (params_abs, batch_abs)
    else:  # decode
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.batch, shape.seq))
        cache_abs = _cache_sharded(cache_abs, cfg, rules, mesh)
        ba = rules["batch"]
        dp = int(np.prod([mesh.shape[a] for a in
                          ((ba,) if isinstance(ba, str) else ba)]))
        tok_spec = P(ba, None) if shape.batch % dp == 0 else P(None, None)
        tokens_abs = _with_sharding(
            jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32),
            NamedSharding(mesh, tok_spec))
        clen = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, tokens, cache_len):
            return model.decode_step(params, cache, tokens, cache_len)

        fn = jax.jit(serve_step, donate_argnums=(1,))
        args = (params_abs, cache_abs, tokens_abs, clen)
    return cfg, mesh, fn, args


class SkipCell(Exception):
    pass


def _compile_cost(arch, shape_name, multi_pod, cfg, seq=None, **kw):
    """Compile one config variant; return (flops, bytes, collectives)."""
    _, mesh, fn, args = prepare_cell(arch, shape_name, multi_pod, cfg=cfg,
                                     seq=seq, **kw)
    compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            _hlo_collective_bytes(compiled.as_text()))


def _collect_kind(c, kind, field):
    return c.get(kind, {field: 0})[field]


def _fit_cell(arch, shape_name, cfg, shape, **kw):
    """cost(L, S) = alpha(S) + L*beta(S), alpha/beta quadratic in S.
    Returns (flops, bytes, collectives) at (n_layers, shape.seq).

    Mamba archs (cost ~ linear in S; zamba2's shared-attn fraction is the
    only quadratic part, <5% of FLOPs) use a fast path: depth extrapolation
    at ONE small seq + linear seq scaling — their unrolled chunk-scan cost
    variants otherwise take many minutes of XLA compile each."""
    l1, l2 = depth_pair(cfg)
    if cfg.mamba_version:
        s0 = min(256, shape.seq)
        (f1, b1, c1) = _compile_cost(arch, shape_name, False,
                                     scale_depth(cfg, l1), seq=s0, **kw)
        (f2, b2, c2) = _compile_cost(arch, shape_name, False,
                                     scale_depth(cfg, l2), seq=s0, **kw)
        full = cfg.n_layers
        scale = shape.seq / s0
        flops = max(0.0, _lin(l1, f1, l2, f2, full)) * scale
        nbytes = max(0.0, _lin(l1, b1, l2, b2, full)) * scale
        colls = {}
        for kind in set(c1) | set(c2):
            colls[kind] = {
                "bytes": max(0.0, _lin(
                    l1, _collect_kind(c1, kind, "bytes"),
                    l2, _collect_kind(c2, kind, "bytes"), full)) * scale,
                "count": max(0.0, _lin(
                    l1, _collect_kind(c1, kind, "count"),
                    l2, _collect_kind(c2, kind, "count"), full)),
            }
        return flops, nbytes, colls, [[l1, f1], [l2, f2]]
    s_pts = seq_points(cfg, shape)
    full_l, full_s = cfg.n_layers, shape.seq
    rows = {}
    for ld in (l1, l2):
        for sq in sorted(set(s_pts)):
            rows[(ld, sq)] = _compile_cost(arch, shape_name, False,
                                           scale_depth(cfg, ld), seq=sq,
                                           **kw)

    def fit(get):
        if len(set(s_pts)) == 1:
            f1 = get(rows[(l1, s_pts[0])])
            f2 = get(rows[(l2, s_pts[0])])
            return _lin(l1, f1, l2, f2, full_l)
        alphas, betas = [], []
        ss = sorted(set(s_pts))
        for sq in ss:
            f1 = get(rows[(l1, sq)])
            f2 = get(rows[(l2, sq)])
            beta = (f2 - f1) / (l2 - l1)
            alphas.append(f1 - l1 * beta)
            betas.append(beta)
        pa = np.polyfit(ss, alphas, 2)
        pb = np.polyfit(ss, betas, 2)
        return float(np.polyval(pa, full_s)
                     + full_l * np.polyval(pb, full_s))

    flops = max(0.0, fit(lambda c: c[0]))
    nbytes = max(0.0, fit(lambda c: c[1]))
    kinds = set()
    for c in rows.values():
        kinds |= set(c[2])
    colls = {k: {"bytes": max(0.0, fit(lambda c, k=k: _collect_kind(c[2], k, "bytes"))),
                 "count": max(0.0, fit(lambda c, k=k: _collect_kind(c[2], k, "count")))}
             for k in kinds}
    return flops, nbytes, colls, [[l, s] for (l, s) in rows]


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             extrapolate: bool = True, **kw) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name, ok=False)
    try:
        cfg, mesh, fn, args = prepare_cell(arch, shape_name, multi_pod, **kw)
        res.n_devices = int(np.prod(list(mesh.shape.values())))
        res.n_params = float(cfg.n_params())
        res.n_active_params = float(cfg.active_params())
        t0 = time.time()
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        res.compile_s = time.time() - t0
        ca = compiled.cost_analysis() or {}
        res.flops_raw = float(ca.get("flops", 0.0))
        res.bytes_raw = float(ca.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        if ma is not None:
            res.argument_bytes = int(ma.argument_size_in_bytes)
            res.output_bytes = int(ma.output_size_in_bytes)
            res.temp_bytes = int(ma.temp_size_in_bytes)
            res.peak_bytes = int(getattr(ma, "peak_memory_in_bytes", 0))
            res.alias_bytes = int(ma.alias_size_in_bytes)
        res.collectives_raw = _hlo_collective_bytes(compiled.as_text())
        del compiled, lowered

        if extrapolate:
            # XLA counts while-loop bodies once: compile reduced variants
            # with unrolled scans and fit cost(L, S) = alpha(S) + L*beta(S)
            # (quadratic alpha/beta in S — exact for this workload family).
            shape = SHAPES[shape_name]
            kw_fit = {k: v for k, v in kw.items() if k != "cfg"}
            if shape.kind in ("train", "prefill"):
                flops, nbytes, colls, pts = _fit_cell(
                    arch, shape_name, cfg, shape, **kw_fit)
            else:  # decode: cost linear in cache length already at full T;
                #    only the layer scans need unrolled-depth extrapolation
                l1, l2 = depth_pair(cfg)
                (f1, b1, c1) = _compile_cost(arch, shape_name, multi_pod,
                                             scale_depth(cfg, l1), **kw_fit)
                (f2, b2, c2) = _compile_cost(arch, shape_name, multi_pod,
                                             scale_depth(cfg, l2), **kw_fit)
                full = cfg.n_layers
                flops = _lin(l1, f1, l2, f2, full)
                nbytes = _lin(l1, b1, l2, b2, full)
                colls = {}
                for kind in set(c1) | set(c2):
                    colls[kind] = {
                        "bytes": max(0.0, _lin(
                            l1, _collect_kind(c1, kind, "bytes"),
                            l2, _collect_kind(c2, kind, "bytes"), full)),
                        "count": max(0.0, _lin(
                            l1, _collect_kind(c1, kind, "count"),
                            l2, _collect_kind(c2, kind, "count"), full)),
                    }
                pts = [[l1, f1], [l2, f2]]
            res.depth_points = pts
            res.flops_per_device = flops
            res.bytes_accessed_per_device = nbytes
            res.collectives = colls
        else:
            res.flops_per_device = res.flops_raw
            res.bytes_accessed_per_device = res.bytes_raw
            res.collectives = res.collectives_raw
        res.ok = True
    except SkipCell as e:
        res.error = f"SKIP: {e}"
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        res.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()[-2000:]}"
    return res


def run_dkpca_cell(multi_pod: bool, n_per_node: int = 512, m_dim: int = 784,
                   hops: int = 2, use_pallas: bool = False,
                   center: str = "global", message_dtype=None,
                   tag: str = "") -> CellResult:
    """The paper's own workload on the production mesh: one network node per
    chip (J = 256 or 512), ring = ICI collective_permutes.

    Per-ADMM-iteration costs are extracted by lowering with n_iters = 2 and
    4 and differencing (the iteration loop is a scan; XLA costs its body
    once). MODEL-flops analog: the analytic per-iteration flop count of
    Alg. 1 (matmul chain of eq. 10-13)."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch="dkpca-paper" + tag,
                     shape=f"N{n_per_node}xM{m_dim}",
                     mesh=mesh_name, ok=False)
    try:
        from ..core.dkpca import dkpca_distributed
        from ..core.kernels_math import KernelSpec
        mesh = make_production_mesh(multi_pod=multi_pod)
        axes = mesh.axis_names
        j = int(np.prod(list(mesh.shape.values())))
        res.n_devices = j
        spec = KernelSpec(kind="rbf", gamma=1e-3)

        def lower_iters(n_iters):
            def fn(x, alpha0):
                r = dkpca_distributed(
                    x, mesh, axes, hops=hops, spec=spec, center=center,
                    n_iters=n_iters, alpha0=alpha0, gamma=1e-3,
                    use_pallas=use_pallas, message_dtype=message_dtype,
                    unroll_iters=True)
                return r.alpha, r.primal_residual
            x_abs = jax.ShapeDtypeStruct((j, n_per_node, m_dim), jnp.float32)
            a_abs = jax.ShapeDtypeStruct((j, n_per_node), jnp.float32)
            return jax.jit(fn).lower(x_abs, a_abs).compile()

        t0 = time.time()
        c2 = lower_iters(2)
        c4 = lower_iters(4)
        res.compile_s = time.time() - t0
        ca2 = c2.cost_analysis() or {}
        ca4 = c4.cost_analysis() or {}
        # per-iteration deltas
        res.flops_per_device = (float(ca4.get("flops", 0))
                                - float(ca2.get("flops", 0))) / 2
        res.bytes_accessed_per_device = (
            float(ca4.get("bytes accessed", 0))
            - float(ca2.get("bytes accessed", 0))) / 2
        co2 = _hlo_collective_bytes(c2.as_text())
        co4 = _hlo_collective_bytes(c4.as_text())
        colls = {}
        for kind in set(co2) | set(co4):
            colls[kind] = {
                "bytes": max(0.0, (_collect_kind(co4, kind, "bytes")
                                   - _collect_kind(co2, kind, "bytes")) / 2),
                "count": max(0.0, (_collect_kind(co4, kind, "count")
                                   - _collect_kind(co2, kind, "count")) / 2),
            }
        res.collectives = colls
        ma = c4.memory_analysis()
        if ma is not None:
            res.argument_bytes = int(ma.argument_size_in_bytes)
            res.peak_bytes = int(getattr(ma, "peak_memory_in_bytes", 0))
            res.temp_bytes = int(ma.temp_size_in_bytes)
        # analytic per-iteration useful flops of Alg. 1 per node:
        # K^-1 B (2 N^2 S), znorm + p (2 S^2 N^2 * 2), alpha solve (6 N^2),
        # eta update (2 N^2) — stored in n_active_params as flops/(2*tokens)
        # analog is meaningless here; keep raw count in n_params field.
        s_slots = 2 * hops + 1
        per_node = (2 * n_per_node ** 2 * s_slots
                    + 4 * s_slots ** 2 * n_per_node ** 2
                    + 8 * n_per_node ** 2)
        res.n_params = float(per_node)          # analytic useful flops/node
        res.n_active_params = float(per_node)
        res.flops_raw = float(ca4.get("flops", 0))
        res.ok = True
    except Exception as e:  # noqa: BLE001
        res.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()[-2000:]}"
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--attention-impl", default=None)
    ap.add_argument("--dkpca", action="store_true",
                    help="also run the paper's own workload cell")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    if args.arch == "dkpca":
        archs = []
        args.dkpca = True
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"both": [False, True], "single": [False],
            "multi": [True]}[args.multipod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if args.resume and os.path.exists(args.out):
        results = {tuple(k.split("|")): v
                   for k, v in json.load(open(args.out)).items()}

    for arch in archs:
        for shape in shapes:
            for mp in pods:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in results and (results[key].get("ok")
                                       or results[key].get("error", "")
                                       .startswith("SKIP")):
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                # cost extrapolation only on the single-pod mesh (the
                # roofline table is single-pod; multi-pod proves lowering)
                r = run_cell(arch, shape, mp, extrapolate=not mp,
                             attention_impl=args.attention_impl)
                results[key] = dataclasses.asdict(r)
                status = "ok" if r.ok else r.error.splitlines()[0]
                print(f"[dryrun] {key} -> {status} "
                      f"({r.compile_s:.1f}s, flops/dev={r.flops_per_device:.3g}, "
                      f"peak={r.peak_bytes / 2 ** 30:.2f}GiB)", flush=True)
                with open(args.out, "w") as f:
                    json.dump({"|".join(k): v for k, v in results.items()},
                              f, indent=1)
    if args.dkpca:
        for mp in pods:
            key = ("dkpca-paper", "N512xM784", "2x16x16" if mp else "16x16")
            if not (key in results and results[key].get("ok")):
                print(f"[dryrun] {key} ...", flush=True)
                r = run_dkpca_cell(mp)
                results[key] = dataclasses.asdict(r)
                print(f"[dryrun] {key} -> "
                      f"{'ok' if r.ok else r.error.splitlines()[0]}",
                      flush=True)
                with open(args.out, "w") as f:
                    json.dump({"|".join(k): v for k, v in results.items()},
                              f, indent=1)

    n_ok = sum(1 for v in results.values() if v["ok"])
    n_skip = sum(1 for v in results.values()
                 if v.get("error", "").startswith("SKIP"))
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed")


if __name__ == "__main__":
    main()
