"""Serving example: batched greedy decode with continuous slot reuse over a
smoke-scale model (same engine code drives the full configs on TPU).

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x22b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--prompts", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(3, 10))).tolist()
               for _ in range(args.prompts)]
    engine = DecodeEngine(model, params, 3,
                          ServeConfig(max_len=48,
                                      max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    outs = engine.generate(prompts)
    dt = time.perf_counter() - t0
    total = sum(map(len, outs))
    print(f"[{cfg.name}] {len(prompts)} prompts -> {total} tokens "
          f"in {dt:.2f}s ({total / max(dt, 1e-9):.1f} tok/s, "
          f"3 slots, continuous batching)")
    for i, o in enumerate(outs):
        print(f"  prompt {i} ({len(prompts[i])} toks) -> {o}")


if __name__ == "__main__":
    main()
