"""Paper Alg. 1 — ADMM-based decentralized kernel PCA (reference simulator).

This is the faithful, graph-general implementation of the paper's algorithm,
fully in the dual (kernel) space. All J nodes are simulated in one process
with vectorized updates; ``repro.core.dkpca`` is the SPMD (shard_map +
collective_permute) production version, validated against this module.

Variables per node j (paper §4.2): all live in dual space —
  alpha_j in R^{N_j}
  B_j = phi(X_j)^T eta_j in R^{N_j x S_j}   (one column per constraint slot)
  G_j = phi(X_j)^T Z xi_j in R^{N_j x S_j}

Constraint slots: the paper's problem (7) has a self constraint
(w_j = P_j z_j, weight rho1) and neighbor consensus constraints
(phi(X_j)alpha_j = P_j z_q, q in Omega_j, weight rho2); its eq. (10)-(13)
write only the neighbor part with uniform rho. We implement the general
per-slot-rho form (slot 0 = self, slots 1..D = neighbors); with
``include_self=False`` and constant rho this reduces exactly to eq. (10)-(13).

One ADMM iteration (uniform-rho form for reference):
  Z:    z_hat_m = sum_{l in slots^-1(m)} phi(X_l)(K_l^-1 B_l[:,m] + rho alpha_l)/rho_bar_m
        z_m = z_hat_m / max(1, ||z_hat_m||)                        (eq. 10-11)
  alpha: alpha_j = [rho_bar K_j - 2 K_j^2]^-1 (rho G_j - B_j) 1    (eq. 12)
  eta:  B_j[:,s] += rho_s (K_j alpha_j - G_j[:,s])                 (eq. 13)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels_math import KernelSpec, center_gram, gram, psd_jitter_eigh, resolve_gamma
from .rho import RhoSchedule, auto_rho
from .topology import Graph


@dataclasses.dataclass(frozen=True)
class DkpcaSetup:
    """Static per-run tensors (trace-time constants are numpy; traced are jnp).

    Slot layout: S = D + 1 where D = max degree. Slot 0 is the self slot
    (masked out when include_self=False), slots 1..D are neighbors in graph
    order. src[j, s] = data-owner node of slot s of node j;
    rsl[j, s] = the slot index of node j inside node src[j,s]'s slot list.
    """

    x: jax.Array          # (J, N, M) node data
    k: jax.Array          # (J, N, N) (centered) local Gram K_j
    lam: jax.Array        # (J, N) floored eigenvalues of K_j (ascending)
    vec: jax.Array        # (J, N, N) eigenvectors of K_j
    kcross: jax.Array     # (J, S, S, N, N) kcross[j,a,b] = K(X_src[j,a], X_src[j,b])
    src: jax.Array        # (J, S) int32
    rsl: jax.Array        # (J, S) int32
    mask: jax.Array       # (J, S) bool — valid slots
    gamma: jax.Array      # scalar RBF bandwidth actually used
    include_self: bool = True

    @property
    def n_nodes(self):
        return self.x.shape[0]

    @property
    def n_local(self):
        return self.x.shape[1]

    @property
    def n_slots(self):
        return self.mask.shape[1]


@dataclasses.dataclass
class DkpcaResult:
    alpha: jax.Array            # (J, N) final local solutions
    alpha_hist: jax.Array       # (T, J, N)
    lagrangian: jax.Array       # (T,) augmented Lagrangian value
    primal_residual: jax.Array  # (T,) ||K alpha 1 - G||_F total
    rho_hist: jax.Array         # (T,) rho2 used per iteration


def _masked_center(kfull: jax.Array, valid: jax.Array) -> jax.Array:
    """Center a square Gram over the valid rows/cols only (then zero the
    invalid ones). kfull: (P, P); valid: (P,) bool."""
    v = valid.astype(kfull.dtype)
    nv = jnp.maximum(jnp.sum(v), 1.0)
    row = (kfull @ v) / nv                               # mean over valid cols
    col = (v @ kfull) / nv
    tot = (v @ kfull @ v) / (nv * nv)
    kc = kfull - row[:, None] - col[None, :] + tot
    return kc * v[:, None] * v[None, :]


def kernel_mean_stats(x_nodes: jax.Array, spec: KernelSpec, gamma):
    """Global kernel mean statistics for consistent centering.

    Returns (m, mu_bar): m[j, i] = mean_t K(x_i^(j), t) over ALL samples t in
    the network, mu_bar = mean over all pairs.

    Decentralized realization (one-time, before ADMM): node j computes
    psi_j(x) = mean_i K(x, x_i^(j)) for every x it can evaluate, and the
    network runs ONE consensus-averaging round on the per-sample partial
    means (a gossip average; a single ``jax.lax.pmean`` on TPU). The paper
    centers per-block instead, which makes cross-blocks inconsistent — see
    EXPERIMENTS.md §Paper-validation for the measured bias.
    """
    j, n, _ = x_nodes.shape

    def row_stats(x_j):
        def acc(carry, x_l):
            s = carry + jnp.sum(gram(spec, x_j, x_l, gamma=gamma), axis=1)
            return s, None
        s, _ = jax.lax.scan(acc, jnp.zeros((n,), x_nodes.dtype), x_nodes)
        return s / (j * n)

    m = jax.lax.map(row_stats, x_nodes)                  # (J, N)
    mu_bar = jnp.mean(m)
    return m, mu_bar


def build_setup(x_nodes: jax.Array, graph: Graph, spec: KernelSpec,
                center: str | bool = "global", include_self: bool = True,
                rel_eps: float = 1e-6) -> DkpcaSetup:
    """Precompute Gram blocks / factorizations; mirrors the paper's setup
    phase where raw data is exchanged with neighbors and all K(X_p, X_q),
    p,q in Omega_j, are formed once.

    center:
      "global" (default) — center every block with the *same* global kernel
        mean statistics (one extra consensus-averaging round at setup, see
        ``kernel_mean_stats``). All nodes then share one centered feature
        space phi(x) - mu, and the consensus fixed point matches centered
        central kPCA (measured similarity -> 1.0).
      "neighborhood" — node j centers the Gram over the data it holds; the
        feature-space offset mu_j then differs per node, which biases the
        consensus fixed point. Kept for ablation.
      "block" — the paper's §6.1 formula applied to every block separately.
        Faithful to the text, but cross-blocks are then centered with
        *different* means per side, which is not a valid Gram of any single
        feature map; we measured the consensus fixed point drifting away
        from the central solution (similarity 0.81 at iter 30 -> 0.70 at
        iter 100 while the primal residual -> 0). Kept for comparison.
      "none"/False — raw kernel (fixed point matches *uncentered* central
        kPCA exactly; similarity 1.000 in our validation).
    """
    if center is True:
        center = "global"
    if center is False:
        center = "none"
    assert center in ("global", "neighborhood", "block", "none")
    x_nodes = jnp.asarray(x_nodes)
    j, n, _ = x_nodes.shape
    assert j == graph.n_nodes
    ids, rev, nmask = graph.neighbor_array()
    d = ids.shape[1]
    s = d + 1
    src = np.concatenate([np.arange(j, dtype=np.int32)[:, None], ids], axis=1)
    rsl = np.concatenate([np.zeros((j, 1), np.int32), rev + 1], axis=1)
    mask = np.concatenate([np.full((j, 1), include_self), nmask], axis=1)
    # slot-0 blocks (K_j) are always needed even when the self *constraint*
    # is disabled, so Gram validity masking uses a mask with slot 0 on.
    gmask = np.concatenate([np.full((j, 1), True), nmask], axis=1)

    gamma = resolve_gamma(spec, x_nodes.reshape(j * n, -1))

    xs = x_nodes[src]                                    # (J, S, N, M)

    if center == "global":
        m_glob, mu_bar = kernel_mean_stats(x_nodes, spec, gamma)
        ms = m_glob[src]                                 # (J, S, N)
    else:
        ms = None

    def node_gram(xs_j, gmask_j, ms_j):
        xflat = xs_j.reshape(s * n, -1)
        kfull = gram(spec, xflat, gamma=gamma)           # (S*N, S*N)
        valid = jnp.repeat(gmask_j, n)
        if center == "neighborhood":
            kfull = _masked_center(kfull, valid)
        elif center == "global":
            mf = ms_j.reshape(s * n)
            kfull = kfull - mf[:, None] - mf[None, :] + mu_bar
            kfull = kfull * valid[:, None] * valid[None, :]
        kb = kfull.reshape(s, n, s, n).transpose(0, 2, 1, 3)
        if center == "block":
            kb = jax.vmap(jax.vmap(center_gram))(kb)
        return kb                                        # (S, S, N, N)

    ms_arg = ms if ms is not None else jnp.zeros((j, s, n), x_nodes.dtype)
    kcross = jax.vmap(node_gram)(xs, jnp.asarray(gmask), ms_arg)

    kj = kcross[:, 0, 0]                                 # (J, N, N)
    lam, vec = jax.vmap(lambda kk: psd_jitter_eigh(kk, rel_eps))(kj)
    return DkpcaSetup(x=x_nodes, k=kj, lam=lam, vec=vec, kcross=kcross,
                      src=jnp.asarray(src), rsl=jnp.asarray(rsl),
                      mask=jnp.asarray(mask), gamma=gamma,
                      include_self=include_self)


def _slot_rho(setup: DkpcaSetup, rho1, rho2):
    """(J, S) per-slot rho (0 on invalid slots)."""
    j, s = setup.mask.shape
    r = jnp.concatenate(
        [jnp.full((j, 1), rho1), jnp.full((j, s - 1), rho2)], axis=1)
    return r * setup.mask


def _solve_kinv(setup: DkpcaSetup, b, rel_thresh=1e-5):
    """K_j^{-1} b (pseudo-inverse on the row space of K_j). b: (J, N, ...)."""
    lam, v = setup.lam, setup.vec
    inv = jnp.where(lam > rel_thresh * lam[:, -1:], 1.0 / lam, 0.0)
    tmp = jnp.einsum("jnm,jm...->jn...", jnp.swapaxes(v, 1, 2), b)
    tmp = tmp * (inv[..., None] if tmp.ndim == 3 else inv)
    return jnp.einsum("jnm,jm...->jn...", v, tmp)


def admm_iteration(setup: DkpcaSetup, alpha, b, rho1, rho2,
                   project: str = "ball"):
    """One ADMM iteration (eq. 10-13, per-slot-rho generalization).

    alpha: (J, N); b: (J, N, S). Returns (alpha', b', g, znorm2).
    """
    mask = setup.mask
    rho_slots = _slot_rho(setup, rho1, rho2)              # (J, S)
    rho_bar = jnp.sum(rho_slots, axis=1)                  # (J,) sum of in-slot
    # rho-weights: by graph symmetry the in-slot weights of node m equal its
    # own out-slot weights (self rho1, neighbors rho2).

    # ---- Z-update -------------------------------------------------------
    # message 1 (sent by src l): m1_l = K_l^{-1} B_l     (per out-slot column)
    m1 = _solve_kinv(setup, b)                            # (J, N, S)
    # gather onto in-slots of each node m: contribution of slot i (owner
    # src[m,i], its out-slot rsl[m,i]):
    #   c[m, i] = (m1_src[:, rsl] + rho_i * alpha_src) / rho_bar_m
    m1_g = m1[setup.src, :, setup.rsl]                    # (J, S, N)
    al_g = alpha[setup.src]                               # (J, S, N)
    c = (m1_g + rho_slots[..., None] * al_g) / rho_bar[:, None, None]
    c = c * mask[..., None]
    # ||z_hat_m||^2 = sum_ab c_a^T K(X_a, X_b) c_b  over in-slots
    znorm2 = jnp.einsum("jan,jabnm,jbm->j", c, setup.kcross, c)
    rs = jax.lax.rsqrt(jnp.maximum(znorm2, 1e-30))
    if project == "sphere":
        # Always renormalize z. Experimental: breaks the dual-variable
        # consistency of the ball-constrained problem (B integrates a
        # persistent residual); kept for ablation only.
        scale = rs
    else:
        # Paper eq. (11): project onto the unit *ball* ("ball"/"rescale").
        # NOTE (§Repro insight): z=0 is then also a stationary point of the
        # iteration; it only sustains while ||z_hat|| >= 1, which the paper's
        # *unnormalized* Gaussian alpha-init gives at t=0 (||alpha0||~sqrt(N))
        # and the "rescale" gauge (see run loop) maintains for t -> inf.
        scale = jnp.where(znorm2 > 1.0, rs, 1.0)
    # p[m, a] = phi(X_src[m,a])^T z_m for every in-slot owner a
    p = scale[:, None, None] * jnp.einsum("jabnm,jbm->jan", setup.kcross, c)
    # deliver: G_j[:, s] = phi(X_j)^T z_{dest of out-slot s} = p[src, rsl]
    g = p[setup.src, setup.rsl] * mask[..., None]         # (J, S, N) slot-major
    g = jnp.swapaxes(g, 1, 2)                             # (J, N, S)

    # ---- alpha-update (eq. 12) -----------------------------------------
    rhs = jnp.sum(rho_slots[:, None, :] * g - b * mask[:, None, :], axis=2)
    lam = setup.lam
    den = rho_bar[:, None] * lam - 2.0 * lam * lam
    # drop (don't invert) directions where the alpha-Hessian is not PD —
    # during the rho warm-up large-N kernels can violate Assumption 2 for a
    # few iterations; clamping would amplify those modes into divergence.
    inv = jnp.where((lam > 1e-5 * lam[:, -1:]) & (den > 0), 1.0 / den, 0.0)
    vt_rhs = jnp.einsum("jnm,jm->jn", jnp.swapaxes(setup.vec, 1, 2), rhs)
    alpha_new = jnp.einsum("jnm,jm->jn", setup.vec, inv * vt_rhs)

    # ---- eta-update (eq. 13) -------------------------------------------
    ka = jnp.einsum("jnm,jm->jn", setup.k, alpha_new)     # (J, N)
    b_new = b + rho_slots[:, None, :] * (ka[..., None] - g)
    b_new = b_new * mask[:, None, :]

    if project == "rescale":
        # Beyond-paper stabilization (gauge renormalization): while no node's
        # ||z_hat|| exceeds 1, the whole iteration is 1-homogeneous in
        # (alpha, B) jointly, so multiplying the state by a global constant
        # replays the *same* trajectory in a different gauge. Rescale so the
        # largest ||z_hat|| sits at the ball boundary; this removes the slow
        # decay into the degenerate z=0 stationary point at long horizons
        # (power iteration on the linear part of the ADMM map).
        zmax = jnp.sqrt(jnp.maximum(jnp.max(znorm2), 1e-30))
        gain = jnp.where(zmax < 1.0, 1.0 / zmax, 1.0)
        alpha_new = alpha_new * gain
        b_new = b_new * gain
    return alpha_new, b_new, g, znorm2


def augmented_lagrangian(setup: DkpcaSetup, alpha, b, g, rho1, rho2):
    """Dual-space evaluation of eq. (8):
    L = sum_j [ -a^T K^2 a + sum_s B_s^T C_s + sum_s rho_s/2 C_s^T K C_s ],
    C_s = alpha - K^{-1} G_s (constraint residual coefficients)."""
    rho_slots = _slot_rho(setup, rho1, rho2)
    ka = jnp.einsum("jnm,jm->jn", setup.k, alpha)
    obj = -jnp.sum(ka * ka, axis=1)                       # -||alpha^T K||^2
    kinv_g = _solve_kinv(setup, g)                        # (J, N, S)
    cres = (alpha[..., None] - kinv_g) * setup.mask[:, None, :]
    lin = jnp.sum(b * cres, axis=(1, 2))
    kc = jnp.einsum("jnm,jms->jns", setup.k, cres)
    quad = 0.5 * jnp.sum(rho_slots[:, None, :] * cres * kc, axis=(1, 2))
    return jnp.sum(obj + lin + quad)


@partial(jax.jit, static_argnames=("setup_static", "n_iters", "project"))
def _run_jit(setup_static, setup_arrays, alpha0, rho1_arr, rho2_arr, n_iters,
             project):
    setup = dataclasses.replace(setup_static, **setup_arrays)

    def step(carry, t):
        alpha, b = carry
        r1, r2 = rho1_arr[t], rho2_arr[t]
        alpha_n, b_n, g, _ = admm_iteration(setup, alpha, b, r1, r2, project)
        # Theorem-2 pairing: L(alpha^t, Z^t, eta^t) with Z^t generated from
        # (alpha^t, eta^t) — i.e. the *incoming* alpha/b with the g computed
        # from them inside this iteration.
        lag = augmented_lagrangian(setup, alpha, b, g, r1, r2)
        ka = jnp.einsum("jnm,jm->jn", setup.k, alpha_n)
        res = jnp.sqrt(jnp.sum(setup.mask[:, None, :]
                               * (ka[..., None] - g) ** 2))
        return (alpha_n, b_n), (alpha_n, lag, res)

    b0 = jnp.zeros(alpha0.shape + (setup.n_slots,), alpha0.dtype)
    (alpha, _), (ahist, lhist, rhist) = jax.lax.scan(
        step, (alpha0, b0), jnp.arange(n_iters))
    return alpha, ahist, lhist, rhist


def initial_alpha(setup: DkpcaSetup, init: str = "paper", seed: int = 0):
    """alpha^(0).

    "paper": entrywise standard normal, *unnormalized* — the scale matters:
      ||alpha0|| ~ sqrt(N) puts ||z_hat|| well above 1 so the ball projection
      (the iteration's only normalization) engages from step one.
    "local": warm start at the local kPCA solution (v1/sqrt(lam1) of K_j),
      i.e. each node starts at its own best guess; ||w_j|| = 1 exactly.
    """
    if init == "paper":
        key = jax.random.PRNGKey(seed)
        return jax.random.normal(key, setup.x.shape[:2], setup.k.dtype)
    if init == "local":
        def top(lam, v):
            return v[:, -1] / jnp.sqrt(jnp.maximum(lam[-1], 1e-12))
        return jax.vmap(top)(setup.lam, setup.vec)
    raise ValueError(init)


def run_admm(setup: DkpcaSetup, n_iters: int = 30,
             rho1: float = 100.0,
             rho2: Optional[RhoSchedule] = None,
             seed: int = 0,
             alpha0: Optional[jax.Array] = None,
             init: str = "paper",
             project: str = "ball") -> DkpcaResult:
    """Run Alg. 1. rho2 defaults to the paper's warm-up schedule
    (10 -> 50 -> 100); pass ``RhoSchedule.constant(auto_rho(...))`` for the
    Theorem-2 regime. ``project="sphere"`` enables the beyond-paper
    renormalization that removes the degenerate z=0 attractor."""
    if rho2 is None:
        rho2 = RhoSchedule()
    if alpha0 is None:
        alpha0 = initial_alpha(setup, init, seed)
    ts = np.arange(n_iters)
    rho2_arr = jnp.asarray([rho2.at(t) for t in ts], setup.k.dtype)
    rho1_arr = jnp.full((n_iters,), rho1, setup.k.dtype) \
        if setup.include_self else jnp.zeros((n_iters,), setup.k.dtype)

    arrays = {f.name: getattr(setup, f.name)
              for f in dataclasses.fields(DkpcaSetup)
              if f.name != "include_self"}
    static = dataclasses.replace(
        setup, **{k: None for k in arrays})
    alpha, ahist, lhist, rhist = _run_jit(
        static, arrays, alpha0, rho1_arr, rho2_arr, n_iters, project)
    return DkpcaResult(alpha=alpha, alpha_hist=ahist, lagrangian=lhist,
                       primal_residual=rhist, rho_hist=rho2_arr)


def theorem2_rho(setup: DkpcaSetup, safety: float = 1.05) -> float:
    """Assumption-2-satisfying constant rho for this setup."""
    degrees = np.asarray(jnp.sum(setup.mask, axis=1))
    return auto_rho(np.asarray(setup.lam), degrees, safety)
