"""Paper Alg. 1 — ADMM-based decentralized kernel PCA (reference simulator).

This is the faithful, graph-general implementation of the paper's algorithm,
fully in the dual (kernel) space. All J nodes are simulated in one process
with vectorized updates; ``repro.core.dkpca`` is the SPMD (shard_map +
collective_permute) production version, validated against this module.

Variables per node j (paper §4.2): all live in dual space —
  alpha_j in R^{N_j}
  B_j = phi(X_j)^T eta_j in R^{N_j x S_j}   (one column per constraint slot)
  G_j = phi(X_j)^T Z xi_j in R^{N_j x S_j}

Constraint slots: the paper's problem (7) has a self constraint
(w_j = P_j z_j, weight rho1) and neighbor consensus constraints
(phi(X_j)alpha_j = P_j z_q, q in Omega_j, weight rho2); its eq. (10)-(13)
write only the neighbor part with uniform rho. We implement the general
per-slot-rho form (slot 0 = self, slots 1..D = neighbors); with
``include_self=False`` and constant rho this reduces exactly to eq. (10)-(13).

One ADMM iteration (uniform-rho form for reference):
  Z:    z_hat_m = sum_{l in slots^-1(m)} phi(X_l)(K_l^-1 B_l[:,m] + rho alpha_l)/rho_bar_m
        z_m = z_hat_m / max(1, ||z_hat_m||)                        (eq. 10-11)
  alpha: alpha_j = [rho_bar K_j - 2 K_j^2]^-1 (rho G_j - B_j) 1    (eq. 12)
  eta:  B_j[:,s] += rho_s (K_j alpha_j - G_j[:,s])                 (eq. 13)

The iteration BODY lives in ``repro.core.solver.admm_step`` (one shared
implementation for this module and the SPMD ``repro.core.dkpca``); this
module supplies the dense transport (all nodes in-process, slot routing by
(src, rsl) indexing), the setup phase, and the whole-history run loop.
``repro.core.solver.run_chunked`` is the resumable chunked driver over the
same step.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels_math import KernelSpec, center_gram, gram, psd_jitter_eigh, resolve_gamma
from .rho import RhoSchedule, auto_rho
from .solver import admm_step, dense_parts, init_state, lagrangian
from .topology import Graph


@dataclasses.dataclass(frozen=True)
class DkpcaSetup:
    """Static per-run tensors (trace-time constants are numpy; traced are jnp).

    Slot layout: S = D + 1 where D = max degree. Slot 0 is the self slot
    (masked out when include_self=False), slots 1..D are neighbors in graph
    order. src[j, s] = data-owner node of slot s of node j;
    rsl[j, s] = the slot index of node j inside node src[j,s]'s slot list.
    """

    x: jax.Array          # (J, N, M) node data
    k: jax.Array          # (J, N, N) (centered) local Gram K_j
    lam: jax.Array        # (J, N) floored eigenvalues of K_j (ascending)
    vec: jax.Array        # (J, N, N) eigenvectors of K_j
    kcross: jax.Array     # (J, S, S, N, N) kcross[j,a,b] = K(X_src[j,a], X_src[j,b])
    src: jax.Array        # (J, S) int32
    rsl: jax.Array        # (J, S) int32
    mask: jax.Array       # (J, S) bool — valid slots
    gamma: jax.Array      # scalar RBF bandwidth actually used
    include_self: bool = True

    @property
    def n_nodes(self):
        return self.x.shape[0]

    @property
    def n_local(self):
        return self.x.shape[1]

    @property
    def n_slots(self):
        return self.mask.shape[1]


@dataclasses.dataclass
class DkpcaResult:
    alpha: jax.Array            # (J, N) final local solutions
    alpha_hist: jax.Array       # (T, J, N)
    lagrangian: jax.Array       # (T,) augmented Lagrangian value
    primal_residual: jax.Array  # (T,) ||K alpha 1 - G||_F total
    rho_hist: jax.Array         # (T,) rho2 used per iteration


def _masked_center(kfull: jax.Array, valid: jax.Array) -> jax.Array:
    """Center a square Gram over the valid rows/cols only (then zero the
    invalid ones). kfull: (P, P); valid: (P,) bool."""
    v = valid.astype(kfull.dtype)
    nv = jnp.maximum(jnp.sum(v), 1.0)
    row = (kfull @ v) / nv                               # mean over valid cols
    col = (v @ kfull) / nv
    tot = (v @ kfull @ v) / (nv * nv)
    kc = kfull - row[:, None] - col[None, :] + tot
    return kc * v[:, None] * v[None, :]


def kernel_mean_stats(x_nodes: jax.Array, spec: KernelSpec, gamma):
    """Global kernel mean statistics for consistent centering.

    Returns (m, mu_bar): m[j, i] = mean_t K(x_i^(j), t) over ALL samples t in
    the network, mu_bar = mean over all pairs.

    Decentralized realization (one-time, before ADMM): node j computes
    psi_j(x) = mean_i K(x, x_i^(j)) for every x it can evaluate, and the
    network runs ONE consensus-averaging round on the per-sample partial
    means (a gossip average; a single ``jax.lax.pmean`` on TPU). The paper
    centers per-block instead, which makes cross-blocks inconsistent — see
    EXPERIMENTS.md §Paper-validation for the measured bias.
    """
    j, n, _ = x_nodes.shape

    def row_stats(x_j):
        def acc(carry, x_l):
            s = carry + jnp.sum(gram(spec, x_j, x_l, gamma=gamma), axis=1)
            return s, None
        s, _ = jax.lax.scan(acc, jnp.zeros((n,), x_nodes.dtype), x_nodes)
        return s / (j * n)

    m = jax.lax.map(row_stats, x_nodes)                  # (J, N)
    mu_bar = jnp.mean(m)
    return m, mu_bar


def build_setup(x_nodes: jax.Array, graph: Graph, spec: KernelSpec,
                center: str | bool = "global", include_self: bool = True,
                rel_eps: float = 1e-6,
                gamma: float | None = None) -> DkpcaSetup:
    """Precompute Gram blocks / factorizations; mirrors the paper's setup
    phase where raw data is exchanged with neighbors and all K(X_p, X_q),
    p,q in Omega_j, are formed once.

    center:
      "global" (default) — center every block with the *same* global kernel
        mean statistics (one extra consensus-averaging round at setup, see
        ``kernel_mean_stats``). All nodes then share one centered feature
        space phi(x) - mu, and the consensus fixed point matches centered
        central kPCA (measured similarity -> 1.0).
      "neighborhood" — node j centers the Gram over the data it holds; the
        feature-space offset mu_j then differs per node, which biases the
        consensus fixed point. Kept for ablation.
      "block" — the paper's §6.1 formula applied to every block separately.
        Faithful to the text, but cross-blocks are then centered with
        *different* means per side, which is not a valid Gram of any single
        feature map; we measured the consensus fixed point drifting away
        from the central solution (similarity 0.81 at iter 30 -> 0.70 at
        iter 100 while the primal residual -> 0). Kept for comparison.
      "none"/False — raw kernel (fixed point matches *uncentered* central
        kPCA exactly; similarity 1.000 in our validation).
    """
    if center is True:
        center = "global"
    if center is False:
        center = "none"
    assert center in ("global", "neighborhood", "block", "none")
    x_nodes = jnp.asarray(x_nodes)
    j, n, _ = x_nodes.shape
    assert j == graph.n_nodes
    ids, rev, nmask = graph.neighbor_array()
    d = ids.shape[1]
    s = d + 1
    src = np.concatenate([np.arange(j, dtype=np.int32)[:, None], ids], axis=1)
    rsl = np.concatenate([np.zeros((j, 1), np.int32), rev + 1], axis=1)
    mask = np.concatenate([np.full((j, 1), include_self), nmask], axis=1)
    # slot-0 blocks (K_j) are always needed even when the self *constraint*
    # is disabled, so Gram validity masking uses a mask with slot 0 on.
    gmask = np.concatenate([np.full((j, 1), True), nmask], axis=1)

    # gamma is normally resolved from the pooled data; a caller that
    # REBUILDS a setup mid-run (e.g. the fault driver after a re-knit, on
    # survivor data only) must pin the original value so the kernel — and
    # therefore the warm-started iterate — stays the same operator.
    if gamma is None:
        gamma = resolve_gamma(spec, x_nodes.reshape(j * n, -1))

    xs = x_nodes[src]                                    # (J, S, N, M)

    if center == "global":
        m_glob, mu_bar = kernel_mean_stats(x_nodes, spec, gamma)
        ms = m_glob[src]                                 # (J, S, N)
    else:
        ms = None

    def node_gram(xs_j, gmask_j, ms_j):
        xflat = xs_j.reshape(s * n, -1)
        kfull = gram(spec, xflat, gamma=gamma)           # (S*N, S*N)
        valid = jnp.repeat(gmask_j, n)
        if center == "neighborhood":
            kfull = _masked_center(kfull, valid)
        elif center == "global":
            mf = ms_j.reshape(s * n)
            kfull = kfull - mf[:, None] - mf[None, :] + mu_bar
            kfull = kfull * valid[:, None] * valid[None, :]
        kb = kfull.reshape(s, n, s, n).transpose(0, 2, 1, 3)
        if center == "block":
            kb = jax.vmap(jax.vmap(center_gram))(kb)
        return kb                                        # (S, S, N, N)

    ms_arg = ms if ms is not None else jnp.zeros((j, s, n), x_nodes.dtype)
    kcross = jax.vmap(node_gram)(xs, jnp.asarray(gmask), ms_arg)

    kj = kcross[:, 0, 0]                                 # (J, N, N)
    lam, vec = jax.vmap(lambda kk: psd_jitter_eigh(kk, rel_eps))(kj)
    return DkpcaSetup(x=x_nodes, k=kj, lam=lam, vec=vec, kcross=kcross,
                      src=jnp.asarray(src), rsl=jnp.asarray(rsl),
                      mask=jnp.asarray(mask), gamma=gamma,
                      include_self=include_self)


def _slot_rho(setup: DkpcaSetup, rho1, rho2):
    """(J, S) per-slot rho (0 on invalid slots)."""
    j, s = setup.mask.shape
    r = jnp.concatenate(
        [jnp.full((j, 1), rho1), jnp.full((j, s - 1), rho2)], axis=1)
    return r * setup.mask


def admm_iteration(setup: DkpcaSetup, alpha, b, rho1, rho2,
                   project: str = "ball"):
    """One ADMM iteration (eq. 10-13, per-slot-rho generalization) through
    the shared step body (``repro.core.solver.admm_step``) over the dense
    transport.

    alpha: (J, N); b: (J, N, S). Returns (alpha', b', g, znorm2).
    """
    ops, comm = dense_parts(setup)
    rho_slots = _slot_rho(setup, rho1, rho2)              # (J, S)
    state = init_state(alpha, setup.n_slots)
    state = dataclasses.replace(state, b=jnp.asarray(b))
    new, _ = admm_step(ops, comm, state, rho_slots, project)
    return new.alpha, new.b, new.g, new.znorm2


def augmented_lagrangian(setup: DkpcaSetup, alpha, b, g, rho1, rho2):
    """Dual-space evaluation of eq. (8):
    L = sum_j [ -a^T K^2 a + sum_s B_s^T C_s + sum_s rho_s/2 C_s^T K C_s ],
    C_s = alpha - K^{-1} G_s (constraint residual coefficients)."""
    ops, _ = dense_parts(setup)
    return lagrangian(ops, alpha, b, g, _slot_rho(setup, rho1, rho2))


@partial(jax.jit, static_argnames=("setup_static", "n_iters", "project"))
def _run_jit(setup_static, setup_arrays, alpha0, rho1_arr, rho2_arr, n_iters,
             project):
    setup = dataclasses.replace(setup_static, **setup_arrays)
    ops, comm = dense_parts(setup)

    def step(carry, t):
        st = carry
        rho_slots = _slot_rho(setup, rho1_arr[t], rho2_arr[t])
        new, res = admm_step(ops, comm, st, rho_slots, project)
        # Theorem-2 pairing: L(alpha^t, Z^t, eta^t) with Z^t generated from
        # (alpha^t, eta^t) — i.e. the *incoming* alpha/b with the g computed
        # from them inside this iteration.
        lag = lagrangian(ops, st.alpha, st.b, new.g, rho_slots)
        return new, (new.alpha, lag, res)

    final, (ahist, lhist, rhist) = jax.lax.scan(
        step, init_state(alpha0, setup.n_slots), jnp.arange(n_iters))
    return final.alpha, ahist, lhist, rhist


def initial_alpha(setup: DkpcaSetup, init: str = "local", seed: int = 0):
    """alpha^(0).

    "paper": entrywise standard normal, *unnormalized* — the scale matters:
      ||alpha0|| ~ sqrt(N) puts ||z_hat|| well above 1 so the ball projection
      (the iteration's only normalization) engages from step one.
    "local": warm start at the local kPCA solution (v1/sqrt(lam1) of K_j),
      i.e. each node starts at its own best guess; ||w_j|| = 1 exactly.
      This warm-starts the consensus variable z at the pooled local
      components, which removes the m=24 transient entirely (measured in
      docs/ADMM_CONVERGENCE.md §Ablations) — hence the default. Requires no
      extra communication: each node eigendecomposes its own K_j, which the
      setup phase already does.
    """
    if init == "paper":
        key = jax.random.PRNGKey(seed)
        return jax.random.normal(key, setup.x.shape[:2], setup.k.dtype)
    if init == "local":
        return jax.vmap(local_solution_alpha)(setup.lam, setup.vec)
    raise ValueError(init)


def local_solution_alpha(lam: jax.Array, vec: jax.Array) -> jax.Array:
    """One node's local kPCA solution v1/sqrt(lam1) (so ||w_j|| = 1).
    lam: (N,) ascending; vec: (N, N). Shared by the reference
    ``initial_alpha(init="local")`` and the SPMD in-node default init.

    The eigenvector sign is whatever eigh returns. Do NOT "canonicalize"
    it per-node (e.g. largest-|entry| positive): a node-local sign rule
    keys on node-specific sample indices and de-correlates the signs
    ACROSS nodes, which makes neighbors' warm starts partially cancel in
    the z-update (measured: m=24 similarity drops from 0.997 back to 0.59
    @ 30 iters). LAPACK's sign is a deterministic function of the matrix,
    and nodes drawing data from one distribution get consistently-signed
    top eigenvectors — the reference and SPMD paths also agree because
    they eigendecompose the same (up to fp noise) centered K_j."""
    return vec[:, -1] / jnp.sqrt(jnp.maximum(lam[-1], 1e-12))


def run_admm(setup: DkpcaSetup, n_iters: int = 30,
             rho1: float = 100.0,
             rho2: Optional[RhoSchedule] = None,
             seed: int = 0,
             alpha0: Optional[jax.Array] = None,
             init: str = "local",
             project: str = "ball") -> DkpcaResult:
    """Run Alg. 1 (whole history in one jitted scan; see
    ``repro.core.solver.run_chunked`` for the resumable chunked driver).

    rho2 defaults to the paper's warm-up schedule (10 -> 50 -> 100); pass
    ``RhoSchedule.constant(auto_rho(...))`` for the Theorem-2 regime.
    ``init`` defaults to the local-solution z warm-start (the measured fix
    for the slow low-m transient — docs/ADMM_CONVERGENCE.md §Ablations);
    ``init="paper"`` restores the paper's Gaussian initialization.
    ``project="sphere"`` enables the beyond-paper renormalization that
    removes the degenerate z=0 attractor."""
    if rho2 is None:
        rho2 = RhoSchedule()
    if alpha0 is None:
        alpha0 = initial_alpha(setup, init, seed)
    ts = np.arange(n_iters)
    rho2_arr = jnp.asarray([rho2.at(t) for t in ts], setup.k.dtype)
    rho1_arr = jnp.full((n_iters,), rho1, setup.k.dtype) \
        if setup.include_self else jnp.zeros((n_iters,), setup.k.dtype)

    arrays = {f.name: getattr(setup, f.name)
              for f in dataclasses.fields(DkpcaSetup)
              if f.name != "include_self"}
    static = dataclasses.replace(
        setup, **{k: None for k in arrays})
    alpha, ahist, lhist, rhist = _run_jit(
        static, arrays, alpha0, rho1_arr, rho2_arr, n_iters, project)
    return DkpcaResult(alpha=alpha, alpha_hist=ahist, lagrangian=lhist,
                       primal_residual=rhist, rho_hist=rho2_arr)


def theorem2_rho(setup: DkpcaSetup, safety: float = 1.05) -> float:
    """Assumption-2-satisfying constant rho for this setup."""
    degrees = np.asarray(jnp.sum(setup.mask, axis=1))
    return auto_rho(np.asarray(setup.lam), degrees, safety)
